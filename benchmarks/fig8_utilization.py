"""Paper Fig. 8: GPU utilization during decode — FlexGen vs KVPR (the
paper reports 85% -> 99% average).

Two sections: the analytic pipeline model (paper systems), and a
measured row from the executable runtime whose StepStats now split the
step into t_wait (fetch stall) / t_compute / t_store — host write-back
used to be silently folded into t_compute (`t_compute = dt - t_wait`
with the store barrier inside dt), overstating device busy time.
"""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import flexgen_step, kvpr_step


def run(print_csv: bool = True):
    arch = "opt-13b"
    rows = []
    for seq in (256, 512, 1024):
        wl = opt_workload(arch, 32, seq, weights_offloaded=True)
        ff = ffn_flops(arch, 32)
        fg = flexgen_step(wl, A100_PCIE4, weights_resident=False,
                          d_ff_flops=ff)
        kv = kvpr_step(wl, A100_PCIE4, "column", weights_resident=False,
                       fine_grained=True, d_ff_flops=ff)
        rows.append((seq, fg.utilization, kv.utilization))
        if print_csv:
            # NOTE: this is compute occupancy (GPU-busy / wall). The
            # paper's Fig. 8 uses nvidia-smi "utilization", which also
            # counts copy-engine activity — hence its higher baseline
            # (85%). The DELTA (KVPR raises busy time by overlapping
            # recompute with transfer) is the comparable quantity.
            print(fmt_row(f"fig8/s{seq}", f"{kv.utilization*100:.1f}",
                          f"flexgen_occupancy={fg.utilization*100:.1f}% "
                          f"kvpr_occupancy={kv.utilization*100:.1f}%"))
    rows.append(run_measured(print_csv))
    return rows


def run_measured(print_csv: bool = True):
    """Measured occupancy split from the executable runtime: t_compute
    vs t_wait as fractions of step wall-clock, with the overlapped host
    write-back (t_store) reported on its own — it runs on the store
    pool, fenced per layer, and is NOT on the step's critical path.

    t_wait itself splits further: t_fence is the share fetch workers
    spent blocked on write-back fences, which resolve only after the
    previous layer's device compute — so occupancy (t_compute/wall) is
    a LOWER bound on device-busy, by up to t_fence."""
    from benchmarks.bench_step_breakdown import run as breakdown
    res = breakdown(mode="kvpr", batch=2, prompt=48, gen=8)["steady"]
    wall = max(res["wall_s"], 1e-9)
    occupancy = res["t_compute_s"] / wall
    if print_csv:
        print(fmt_row(
            "fig8/measured", f"{occupancy*100:.1f}",
            f"compute={res['t_compute_s']*1e3:.1f}ms "
            f"wait={res['t_wait_s']*1e3:.1f}ms "
            f"(fence_overlap={res['t_fence_s']*1e3:.1f}ms) "
            f"store_overlapped={res['t_store_s']*1e3:.1f}ms "
            f"retraces={res['retraces']}"))
    return ("measured", occupancy, res["t_store_s"])


if __name__ == "__main__":
    run()
