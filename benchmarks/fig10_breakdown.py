"""Paper Fig. 10: runtime breakdown of an MHA block during decode.
Paper: KV transfer 58% -> 38%, activation transfer 8%, GPU compute
2.3% -> 13.3%."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import flexgen_step, kvpr_step


def run(print_csv: bool = True):
    arch = "opt-13b"
    wl = opt_workload(arch, 32, 1024, weights_offloaded=True)
    fg = flexgen_step(wl, A100_PCIE4, weights_resident=False)
    kv = kvpr_step(wl, A100_PCIE4, "column", weights_resident=False,
                   fine_grained=True)
    rows = []
    for name, st in (("flexgen", fg), ("kvpr", kv)):
        tot = st.t_weights + st.t_act + st.t_kv + st.t_recomp + st.t_attn
        parts = {
            "weights%": 100 * st.t_weights / tot,
            "act%": 100 * st.t_act / tot,
            "kv%": 100 * st.t_kv / tot,
            "gpu%": 100 * (st.t_recomp + st.t_attn) / tot,
        }
        rows.append((name, parts))
        if print_csv:
            print(fmt_row(
                f"fig10/{name}", f"{tot*1e6:.1f}",
                " ".join(f"{k}={v:.1f}" for k, v in parts.items())))
    return rows


if __name__ == "__main__":
    run()
