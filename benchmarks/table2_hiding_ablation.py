"""Paper Table 2 (ablation): hiding KV recomputation under the MHA weight
load (fine-grained pipeline, Fig. 5). Small KV caches + offloaded weights:
weight transfer dominates, so KVPR-without-hiding can lose to FlexGen; the
fine-grained pipeline must be no worse than the baseline."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, layers_of, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import flexgen_step, kvpr_step


def run(print_csv: bool = True):
    arch = "opt-6.7b"
    rows = []
    for batch in (1, 2, 4, 8, 16, 32):
        wl = opt_workload(arch, batch, 256, weights_offloaded=True)
        ff = ffn_flops(arch, batch)
        fg = flexgen_step(wl, A100_PCIE4, weights_resident=False,
                          d_ff_flops=ff)
        coarse = kvpr_step(wl, A100_PCIE4, "column",
                           weights_resident=False, fine_grained=False,
                           d_ff_flops=ff)
        fine = kvpr_step(wl, A100_PCIE4, "column",
                         weights_resident=False, fine_grained=True,
                         d_ff_flops=ff)
        rows.append((batch, fg.t_layer, coarse.t_layer, fine.t_layer))
        if print_csv:
            print(fmt_row(
                f"table2/b{batch}", f"{fine.t_layer*1e6:.1f}",
                f"flexgen_ms={fg.t_layer*1e3:.3f} "
                f"kvpr_nohide_ms={coarse.t_layer*1e3:.3f} "
                f"kvpr_hide_ms={fine.t_layer*1e3:.3f} "
                f"hide_no_worse={fine.t_layer <= fg.t_layer * 1.0001}"))
    return rows


if __name__ == "__main__":
    run()
