"""Fault-layer overhead and recovery-latency benchmark.

The robustness PR threads a fault-injection/recovery layer (op hooks,
bounded fence waits, retry loops) through the decode hot path — this
benchmark proves the layer is FREE when idle and measures what
recovery costs when it is not:

  off        faults=None: the plain hot path.  Gate: its step-time
             FLOOR stays within ``GATE_PCT`` of the committed PR 6
             baseline (BENCH_step_breakdown.json, kvpr/jnp cell),
             i.e. the fault plumbing's disabled-path overhead is
             noise.  The floor estimate is min over BOTH the off and
             idle samples: idle runs strictly more work (every off op
             plus the hook dispatch), so any idle sample is a valid
             upper bound on the off floor — pooling doubles the
             samples without biasing the gate optimistic.
  idle       a FaultPolicy attached but injecting nothing: the hook
             dispatch overhead itself (same-process comparison, so
             machine noise cancels).
  recovery   deterministic transient fetch failures (fail_first)
             retried with exponential backoff: wall-clock penalty per
             recovered fault and the retry count the runtime surfaces
             in StepStats.

    PYTHONPATH=src python benchmarks/bench_faults.py [--smoke]
        [--json out.json] [--repeats N]

--smoke exits non-zero when the off-path gate fails or a recovery run
diverges from the no-fault tokens.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.faults import FaultPolicy
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model

#: PR 6 committed baseline (BENCH_step_breakdown.json kvpr/jnp) used
#: when the snapshot is missing; the snapshot wins when present.
FALLBACK_BASELINE_MS = 11.948
GATE_PCT = 2.0


def _baseline_ms(root: pathlib.Path) -> float:
    p = root / "BENCH_step_breakdown.json"
    try:
        with open(p) as f:
            d = json.load(f)
        return float(d["cells"]["kvpr/jnp"]["steady"]["step_ms"])
    except Exception:
        return FALLBACK_BASELINE_MS


def _spill(cfg, model, params, toks, gen):
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    store = HostKVStore(cfg, toks.shape[0], toks.shape[1] + gen + 2)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs),
                    toks.shape[1])
    return store, first


def _decode_once(rt, cfg, model, params, toks, gen, policy=None):
    """One timed steady decode (fresh spill; fault schedule replayed
    from the policy's start when one is attached)."""
    store, first = _spill(cfg, model, params, toks, gen)
    if policy is not None:
        policy.reset()
    t0 = time.perf_counter()
    tokens, stats = rt.decode(store, first, gen)
    return time.perf_counter() - t0, np.asarray(tokens), stats


def run(batch: int = 2, prompt: int = 48, gen: int = 16,
        repeats: int = 3, root: pathlib.Path = pathlib.Path(".")
        ) -> dict:
    cfg = get_smoke_config("opt-6.7b").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        (batch, prompt)).astype(np.int32)
    sched = Scheduler(profile_system())
    baseline_ms = _baseline_ms(root)

    n_faults, backoff_s = 4, 1e-3
    policy = FaultPolicy(fail_first={"fetch": n_faults})
    # the three measured phases; repeats are INTERLEAVED round-robin
    # (never phase-by-phase) so slow-start machine state — cgroup
    # quota burned by the compile warmup, thermal ramp — biases every
    # phase equally instead of whichever ran first
    rt_off = OffloadDecodeRuntime(cfg, params, scheduler=sched,
                                  mode="kvpr")
    rt_idle = OffloadDecodeRuntime(cfg, params, scheduler=sched,
                                   mode="kvpr", faults=FaultPolicy())
    rt_rec = OffloadDecodeRuntime(cfg, params, scheduler=sched,
                                  mode="kvpr", faults=policy,
                                  io_retries=n_faults,
                                  io_backoff_s=backoff_s)
    try:
        best = {"off": None, "idle": None, "rec": None}
        ref_tokens = idle_tokens = rec_tokens = rec_stats = None
        for phase_rt, key in ((rt_off, "off"), (rt_idle, "idle"),
                              (rt_rec, "rec")):          # warmup all
            _decode_once(phase_rt, cfg, model, params, toks, gen,
                         policy=policy if key == "rec" else None)
        for _ in range(repeats):
            dt, ref_tokens, _ = _decode_once(rt_off, cfg, model,
                                             params, toks, gen)
            best["off"] = dt if best["off"] is None \
                else min(best["off"], dt)
            dt, idle_tokens, _ = _decode_once(rt_idle, cfg, model,
                                              params, toks, gen)
            best["idle"] = dt if best["idle"] is None \
                else min(best["idle"], dt)
            dt, rec_tokens, rec_stats = _decode_once(
                rt_rec, cfg, model, params, toks, gen, policy=policy)
            best["rec"] = dt if best["rec"] is None \
                else min(best["rec"], dt)
    finally:
        rt_off.close()
        rt_idle.close()
        rt_rec.close()
    t_off, t_idle, t_rec = best["off"], best["idle"], best["rec"]
    retries = sum(st.retries for st in rec_stats)

    off_ms = t_off / gen * 1e3
    idle_ms = t_idle / gen * 1e3
    rec_ms = t_rec / gen * 1e3
    # idle does strictly more work than off, so idle samples are valid
    # upper bounds on the off floor — pool them (see module docstring)
    floor_ms = min(off_ms, idle_ms)
    overhead_pct = (floor_ms - baseline_ms) / baseline_ms * 100.0
    gate_ok = overhead_pct < GATE_PCT
    out = {
        "benchmark": "fault_layer",
        "config": {"mode": "kvpr", "batch": batch, "prompt": prompt,
                   "gen": gen, "repeats": repeats,
                   "num_layers": cfg.num_layers, "d_model": cfg.d_model},
        "baseline": {"step_ms": baseline_ms,
                     "source": "BENCH_step_breakdown.json kvpr/jnp"},
        "off": {"step_ms": round(off_ms, 3),
                "floor_step_ms": round(floor_ms, 3),
                "overhead_vs_baseline_pct": round(overhead_pct, 2)},
        "idle": {"step_ms": round(idle_ms, 3),
                 "overhead_vs_off_pct":
                     round((idle_ms - off_ms) / off_ms * 100.0, 2),
                 "tokens_identical":
                     bool(np.array_equal(idle_tokens, ref_tokens))},
        "recovery": {
            "injected_faults": n_faults,
            "retries": int(retries),
            "backoff_s": backoff_s,
            "step_ms": round(rec_ms, 3),
            "recovery_latency_ms": round(t_rec * 1e3 - off_ms * gen, 3),
            "per_fault_ms": round((t_rec - t_off) / n_faults * 1e3, 3),
            "tokens_identical":
                bool(np.array_equal(np.asarray(rec_tokens), ref_tokens)),
        },
        "gate": {"limit_pct": GATE_PCT, "ok": bool(gate_ok)},
    }
    out["smoke_ok"] = bool(gate_ok
                           and out["idle"]["tokens_identical"]
                           and out["recovery"]["tokens_identical"]
                           and retries == n_faults)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="exit 1 on a failed overhead gate or any "
                         "token divergence under recovery")
    args = ap.parse_args(argv)

    res = run(batch=args.batch, prompt=args.prompt, gen=args.gen,
              repeats=args.repeats)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        print(f"SMOKE FAIL: fault-layer gate "
              f"(off overhead {res['off']['overhead_vs_baseline_pct']}% "
              f">= {GATE_PCT}% of baseline "
              f"{res['baseline']['step_ms']}ms, or recovery diverged: "
              f"idle_identical={res['idle']['tokens_identical']} "
              f"rec_identical={res['recovery']['tokens_identical']} "
              f"retries={res['recovery']['retries']})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
