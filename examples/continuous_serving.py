"""Example: the request-level serving API under continuous
(iteration-level) batching — resident AND offloaded — plus streaming,
early EOS, and int4 KV streaming, on a small dense model.

  PYTHONPATH=src python examples/continuous_serving.py

1. Serves a bursty queue of variable-length requests through
   ``LLMEngine`` with ``EngineConfig(batching="continuous")``
   (Orca-style slot admission; no cross-request padding) and verifies
   against one-at-a-time serving.
2. Re-serves the same queue with ``backend="offload"``: the paper's
   KVPR host-offload runtime under iteration-level admission — requests
   are prefilled into free HostKVStore slots mid-decode and the
   scheduler's ExecutionPlan picks a per-slot split for the ragged
   lengths.  Exact: generations still match one-at-a-time resident
   serving.
3. Streams a mixed batch (greedy + temperature + early-EOS requests)
   with ``generate_stream`` — the EOS request frees its slot mid-decode
   and the next queued request is admitted into it.
4. Serves with the host KV store quantized to int4 (paper §4.4 made
   executable), and reports token agreement.
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, LLMEngine, Request,
                           SamplingParams)


def main():
    cfg = get_smoke_config("llama3.2-1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)

    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(6, 24))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(3, 8)))
            for i in range(6)]
    one = LLMEngine.from_config(model, params,
                                EngineConfig(backend="resident"))

    print(f"== continuous batching: {len(reqs)} requests, 2 slots ==")
    t0 = time.perf_counter()
    with LLMEngine.from_config(
            model, params,
            EngineConfig(batching="continuous", slots=2,
                         max_len=64)) as eng_cont:
        cont = eng_cont.generate(reqs)
    t_cont = time.perf_counter() - t0
    ok = all(np.array_equal(c.tokens, one.generate([r])[0].tokens)
             for r, c in zip(reqs, cont))
    print(f"   all {len(reqs)} generations match one-at-a-time serving: "
          f"{ok}  ({t_cont:.1f}s)")

    print("== continuous batching over the KVPR offload runtime ==")
    sched = Scheduler()          # profiles the machine once, caches plans
    t0 = time.perf_counter()
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend="offload", batching="continuous",
                         slots=2, max_len=64),
            scheduler=sched) as eng_off:
        cont_off = eng_off.generate(reqs)
    t_off = time.perf_counter() - t0
    ok_off = all(np.array_equal(c.tokens, one.generate([r])[0].tokens)
                 for r, c in zip(reqs, cont_off))
    print(f"   mid-decode admission over host-offloaded KV, per-slot "
          f"splits: match={ok_off}  ({t_off:.1f}s, "
          f"plan misses={sched.misses})")

    print("== streaming a mixed batch (greedy + temperature + EOS) ==")
    eos = int(cont[0].tokens[1])         # greedy token #2 of request 0
    sps = [SamplingParams(max_tokens=6, eos_id=eos),
           SamplingParams(max_tokens=6, temperature=0.9, top_k=40,
                          seed=1),
           SamplingParams(max_tokens=6)]
    finish = {}
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend="offload", batching="continuous",
                         slots=2, max_len=64), scheduler=sched) as eng:
        for ev in eng.generate_stream(reqs[:3], sps):
            if ev.finish_reason:
                finish[ev.uid] = (ev.finish_reason, ev.index + 1,
                                  ev.step)
    for uid, (reason, n, step) in sorted(finish.items()):
        print(f"   uid={uid}: finish={reason!r} after {n} tokens "
              f"(engine step {step})")

    print("== int4-compressed KVPR offload serving ==")
    uni = [rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
           for _ in range(2)]
    sp = SamplingParams(max_tokens=5)
    with LLMEngine.from_config(
            model, params, EngineConfig(backend="offload")) as e1:
        exact = e1.generate(uni, sp)
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend="offload", compress="int4")) as e2:
        quant = e2.generate(uni, sp)
    agree = np.mean([np.mean(e.tokens == q.tokens)
                     for e, q in zip(exact, quant)])
    print(f"   token agreement exact-vs-int4: {agree*100:.0f}% "
          f"(int4 streams ~4x fewer KV bytes; recomputed prefix exact)")
    one.close()


if __name__ == "__main__":
    main()
