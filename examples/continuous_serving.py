"""Example: continuous (iteration-level) batching — resident AND
offloaded — plus int4 KV streaming, on a small dense model.

  PYTHONPATH=src python examples/continuous_serving.py

1. Serves a bursty queue of variable-length requests through the
   ContinuousBatchingEngine (Orca-style slot admission; no cross-request
   padding) and verifies against one-at-a-time serving.
2. Re-serves the same queue with mode="offload": the paper's KVPR
   host-offload runtime under iteration-level admission — requests are
   prefetched into free HostKVStore slots mid-decode and the scheduler's
   ExecutionPlan picks a per-slot split for the ragged lengths.  Exact:
   generations still match one-at-a-time resident serving.
3. Serves through the offload engine with the host KV store quantized
   to int4 (paper §4.4 made executable), and reports token agreement.
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_smoke_config("llama3.2-1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)

    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(6, 24))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(3, 8)))
            for i in range(6)]

    print(f"== continuous batching: {len(reqs)} requests, 2 slots ==")
    t0 = time.perf_counter()
    cont = ContinuousBatchingEngine(model, params, num_slots=2,
                                    max_len=64).serve(reqs)
    t_cont = time.perf_counter() - t0
    eng = ServingEngine(model, params, mode="resident")
    ok = all(np.array_equal(c.tokens, eng.serve([r])[0].tokens)
             for r, c in zip(reqs, cont))
    print(f"   all {len(reqs)} generations match one-at-a-time serving: "
          f"{ok}  ({t_cont:.1f}s)")

    print("== continuous batching over the KVPR offload runtime ==")
    sched = Scheduler()          # profiles the machine once, caches plans
    t0 = time.perf_counter()
    cont_off = ContinuousBatchingEngine(
        model, params, num_slots=2, max_len=64, mode="offload",
        scheduler=sched).serve(reqs)
    t_off = time.perf_counter() - t0
    ok_off = all(np.array_equal(c.tokens, eng.serve([r])[0].tokens)
                 for r, c in zip(reqs, cont_off))
    print(f"   mid-decode admission over host-offloaded KV, per-slot "
          f"splits: match={ok_off}  ({t_off:.1f}s, "
          f"plan misses={sched.misses})")

    print("== int4-compressed KVPR offload serving ==")
    uni = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 16).astype(np.int32), max_new_tokens=5)
        for i in range(2)]
    exact = ServingEngine(model, params, mode="offload").serve(uni)
    quant = ServingEngine(model, params, mode="offload",
                          compress="int4").serve(uni)
    agree = np.mean([np.mean(e.tokens == q.tokens)
                     for e, q in zip(exact, quant)])
    print(f"   token agreement exact-vs-int4: {agree*100:.0f}% "
          f"(int4 streams ~4x fewer KV bytes; recomputed prefix exact)")


if __name__ == "__main__":
    main()
