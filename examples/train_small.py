"""End-to-end training driver: train a ~100M-param llama-style model for a
few hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_small.py --steps 300
(defaults to a reduced model so it finishes on CPU; --d-model 768
--layers 12 gives the full ~100M)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, make_stream
from repro.models.transformer import Model
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt", default="/tmp/repro_train.msgpack")
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b").replace(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(4, args.d_model // 64), num_kv_heads=2,
        d_ff=args.d_model * 4, vocab_size=args.vocab,
        max_seq_len=args.seq * 2)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch)
    stream = make_stream(dc)

    def jnp_stream():
        for b in stream:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    hist, params, opt_state = train(
        model, params, jnp_stream(), steps=args.steps,
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=20,
                            total_steps=args.steps))
    checkpoint.save(args.ckpt, {"params": params, "config": cfg.name})
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(from {hist['loss'][0]:.4f}); checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
