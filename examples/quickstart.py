"""Quickstart: KVPR in 60 seconds.

1. Profile the system (link bandwidth + GEMM throughput).
2. Ask the scheduler for the optimal KV split point (paper Eq. 10-11).
3. Serve a small OPT-style model twice — resident KV cache vs KVPR
   host-offloaded cache — and check the generations match exactly.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import A100_PCIE4, Workload, flexgen_step, kvpr_step, optimal_split
from repro.core.profiler import profile_system
from repro.models.transformer import Model
from repro.serving import EngineConfig, LLMEngine, SamplingParams


def main():
    # --- 1. profile ------------------------------------------------------
    hw = profile_system()
    print(f"profiled: link={hw.link_bandwidth/1e9:.1f} GB/s "
          f"gemm={hw.gpu_flops/1e9:.0f} GFLOP/s")

    # --- 2. schedule (the paper's LP, on the paper's A100 system) --------
    wl = Workload(batch=32, seq_len=1024, d_model=4096, kv_dim=4096,
                  dtype_bytes=2)
    split = optimal_split(wl, A100_PCIE4, schedule="row")
    fg = flexgen_step(wl, A100_PCIE4)
    kv = kvpr_step(wl, A100_PCIE4, schedule="row")
    print(f"optimal split l={split.l}/{wl.seq_len}: per-layer "
          f"{fg.t_layer*1e3:.2f}ms (full transfer) -> "
          f"{kv.t_layer*1e3:.2f}ms (KVPR), "
          f"{(1 - kv.t_layer/fg.t_layer)*100:.1f}% lower")

    # --- 3. serve: resident vs offloaded-with-recompute ------------------
    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(2)]
    sampling = SamplingParams(max_tokens=8)       # greedy, no early stop

    with LLMEngine.from_config(
            model, params, EngineConfig(backend="resident")) as eng:
        res = eng.generate(prompts, sampling)
    with LLMEngine.from_config(
            model, params, EngineConfig(backend="offload", hw=hw)) as eng:
        off = eng.generate(prompts, sampling)
    for r, o in zip(res, off):
        assert np.array_equal(r.tokens, o.tokens), "KVPR must be exact"
        print(f"req {r.uid}: {r.tokens} (offload == resident ✓, "
              f"finish={o.finish_reason})")
    print("KVPR partial recomputation is exact; no approximation.")


if __name__ == "__main__":
    main()
