"""End-to-end serving driver (the paper's setting): batched requests
against a host-offloaded KV cache, comparing FlexGen-style full transfer
vs KVPR partial recomputation on real wall-clock.

    PYTHONPATH=src python examples/serve_offload.py --arch opt-6.7b \
        --batch 4 --prompt 64 --gen 16
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config, get_config
from repro.core.profiler import profile_system
from repro.models.transformer import Model
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-6.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs much more RAM)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    hw = profile_system()
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        args.prompt).astype(np.int32),
                    max_new_tokens=args.gen) for i in range(args.batch)]

    sampling = SamplingParams(max_tokens=args.gen)
    results = {}
    for name, eng in [
        ("flexgen (full KV transfer)",
         LLMEngine.from_config(model, params, EngineConfig(
             backend="offload", hw=hw, kvpr=False))),
        ("kvpr (partial recompute)",
         LLMEngine.from_config(model, params, EngineConfig(
             backend="offload", hw=hw, kvpr=True))),
    ]:
        t0 = time.perf_counter()
        with eng:
            gens = eng.generate(reqs, sampling)
        dt = time.perf_counter() - t0
        tput = args.batch * args.gen / gens[0].decode_time
        results[name] = (gens, tput)
        print(f"{name:32s} decode {gens[0].decode_time:.2f}s "
              f"({tput:.1f} tok/s)  total {dt:.2f}s")

    g_f = results["flexgen (full KV transfer)"][0]
    g_k = results["kvpr (partial recompute)"][0]
    for a, b in zip(g_f, g_k):
        assert np.array_equal(a.tokens, b.tokens), "KVPR changed outputs!"
    print("outputs identical across modes ✓")


if __name__ == "__main__":
    main()
